"""SLO-aware artifact router (ISSUE 5): Plan.export_catalog -> Router.

Acceptance contract: two requests with different ``latency_budget_s``
land on *different* frontier artifacts from one ``Plan.export_catalog``
output; requests nothing can satisfy are rejected (or flagged); a
tampered catalog member is refused through the existing ArtifactError
paths; and a serve run's measured decode step recalibrates the replay
oracle that planned the artifact.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.api import (CPruneConfig, DeploymentArtifact, MeasuredOracle,
                       MeasurementConfig, MeasurementLog, PruningSession,
                       TrainHooks, Workload, plan)
from repro.api.artifact import ArtifactError
from repro.configs import get_reduced_config
from repro.core import clear_tuning_caches
from repro.serve.engine import Request, ServeEngine
from repro.serve.router import ArtifactCatalog, RouteError, Router


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


def _cfg():
    return get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)


def _count(p):
    return sum(int(np.prod(np.asarray(x).shape)) for x in jax.tree.leaves(p))


@pytest.fixture(scope="module")
def catalog_dir(tmp_path_factory):
    """One plan, two frontier artifacts with a real accuracy/latency
    trade-off: deep uniform pruning (fast, less accurate) vs shallow
    FPGM pruning (slower, more accurate)."""
    clear_tuning_caches()
    cfg = _cfg()
    params = init = jax.random.PRNGKey(0)
    from repro.models.model import init_params
    params = init_params(init, cfg)
    n0 = _count(params)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: _count(p) / n0)
    pl = plan(cfg, accuracy_floor=0.0, targets=["tpu_v5e"],
              strategies=["uniform_l1", "fpgm"],
              workload=Workload(tokens_global=8192), hooks=hooks,
              params=params,
              pcfg=CPruneConfig(a_g=0.0, seq_len=64),
              strategy_kwargs={"uniform_l1": {"ratio": 0.6},
                               "fpgm": {"ratio": 0.1}})
    assert len(pl.frontier) == 2        # the trade-off is real
    path = tmp_path_factory.mktemp("fleet")
    cat = pl.export_catalog(str(path), max_batch=2, max_seq=24)
    assert len(cat) == 2
    clear_tuning_caches()
    return str(path), cfg


def _entries(cat):
    fast = min(cat, key=lambda e: e.predicted_step_s)
    accurate = max(cat, key=lambda e: e.accuracy)
    return fast, accurate


def _req(rng, cfg, rid, **kw):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4, **kw)


def test_catalog_roundtrips_and_matches_artifact_metadata(catalog_dir):
    path, _ = catalog_dir
    cat = ArtifactCatalog.load(path)
    assert sorted(cat.names) == ["fpgm@tpu_v5e", "uniform_l1@tpu_v5e"]
    fast, accurate = _entries(cat)
    assert fast.name != accurate.name
    assert fast.predicted_step_s < accurate.predicted_step_s
    assert fast.accuracy < accurate.accuracy
    for e in cat:
        art = cat.artifact(e.name)
        assert art.metadata["final_acc"] == e.accuracy
        assert art.metadata["latency_total_s"] == e.latency_s
        assert art.metadata["predicted_step_s"] == e.predicted_step_s
        assert art.tuned_digest == e.tuned_digest
        assert e.name in cat.summary()


def test_router_dispatches_by_latency_budget(catalog_dir):
    """The acceptance criterion: one catalog, two budgets, two artifacts.
    A loose budget buys the accurate model; a tight one only fits the
    fast model — and both actually serve."""
    path, cfg = catalog_dir
    cat = ArtifactCatalog.load(path)
    fast, accurate = _entries(cat)
    router = Router(cat)
    rng = np.random.default_rng(0)
    n_new = 4
    tight = (fast.predicted_step_s + accurate.predicted_step_s) / 2 * n_new
    loose = accurate.predicted_step_s * n_new * 100
    r_tight = _req(rng, cfg, 0, latency_budget_s=tight)
    r_loose = _req(rng, cfg, 1, latency_budget_s=loose)
    assert router.submit(r_tight) == fast.name
    assert router.submit(r_loose) == accurate.name
    assert r_tight.routed_to != r_loose.routed_to
    stats = router.run()
    assert stats["requests"] == 2
    assert stats["routing"] == {fast.name: 1, accurate.name: 1}
    assert stats["per_artifact"][fast.name]["requests"] == 1
    assert stats["per_artifact"][accurate.name]["requests"] == 1
    assert r_tight.done and r_loose.done
    assert len(r_tight.output) == len(r_loose.output) == n_new
    # different pruned params -> (here) different greedy continuations
    assert stats["total_new_tokens"] == 2 * n_new


def test_router_respects_accuracy_floor_and_cheapest_policy(catalog_dir):
    path, cfg = catalog_dir
    cat = ArtifactCatalog.load(path)
    fast, accurate = _entries(cat)
    rng = np.random.default_rng(1)
    # cheapest-satisfying policy: no floor -> the fast entry
    router = Router(cat, policy="cheapest")
    assert router.route(_req(rng, cfg, 0)).name == fast.name
    # a floor above the fast entry forces the accurate one even there
    floor = (fast.accuracy + accurate.accuracy) / 2
    assert router.route(
        _req(rng, cfg, 1, accuracy_floor=floor)).name == accurate.name
    # default policy spends a missing budget on quality
    assert Router(cat).route(_req(rng, cfg, 2)).name == accurate.name


def test_router_rejects_or_flags_unsatisfiable_requests(catalog_dir):
    path, cfg = catalog_dir
    cat = ArtifactCatalog.load(path)
    fast, _ = _entries(cat)
    rng = np.random.default_rng(2)
    router = Router(cat)
    with pytest.raises(RouteError, match="no catalog entry satisfies"):
        router.submit(_req(rng, cfg, 0, latency_budget_s=1e-12))
    with pytest.raises(RouteError, match="accuracy_floor=2.0"):
        router.submit(_req(rng, cfg, 1, accuracy_floor=2.0))
    assert router.stats()["rejected"] == 2

    flagging = Router(cat, on_unroutable="flag")
    r = _req(rng, cfg, 2, latency_budget_s=1e-12)
    assert flagging.submit(r) == fast.name      # best effort: fastest
    assert r.slo_infeasible
    stats = flagging.run()
    assert stats["flagged"] == 1
    assert stats["budgeted_requests"] == 1
    assert stats["budget_violations"] == 1      # 1e-12s was never happening
    assert stats["budget_violation_rate"] == 1.0


def test_catalog_load_rejects_tampering(catalog_dir, tmp_path):
    import shutil

    path, _ = catalog_dir
    # a tampered member fails the artifact's own fingerprint validation
    broken = str(tmp_path / "fleet_params")
    shutil.copytree(path, broken)
    member = os.path.join(broken, sorted(os.listdir(broken))[0])
    if not os.path.isdir(member):
        member = os.path.join(broken, "fpgm@tpu_v5e")
    flat = dict(np.load(os.path.join(member, "params.npz")))
    key = sorted(flat)[0]
    flat[key] = flat[key] + 1.0
    with open(os.path.join(member, "params.npz"), "wb") as f:
        np.savez(f, **flat)
    with pytest.raises(ArtifactError, match="params"):
        ArtifactCatalog.load(broken)

    # a manifest whose routing numbers disagree with the artifact is
    # refused too (the router must route by the artifact's real numbers)
    edited = str(tmp_path / "fleet_manifest")
    shutil.copytree(path, edited)
    manifest = os.path.join(edited, "catalog.json")
    with open(manifest) as f:
        blob = json.load(f)
    blob["entries"][0]["accuracy"] = 0.999999
    with open(manifest, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ArtifactError, match="does not match"):
        ArtifactCatalog.load(edited)

    # unknown manifest versions and missing manifests are clear errors
    with open(manifest) as f:
        blob = json.load(f)
    blob["accuracy_floor"] = None
    blob["version"] = 99
    with open(manifest, "w") as f:
        json.dump(blob, f)
    with pytest.raises(ArtifactError, match="version"):
        ArtifactCatalog.load(edited)
    with pytest.raises(ArtifactError, match="missing"):
        ArtifactCatalog.load(str(tmp_path / "nowhere"))


_FAST = MeasurementConfig(warmup=0, repeats=1, trim=0, measure_top_k=1,
                          max_grid_steps=1)


def test_serve_measurements_recalibrate_the_replay_oracle(tmp_path):
    """The oracle feedback loop: a replay-backed artifact is served with a
    MeasurementLog attached; folding the observed decode step back via
    ``recalibrated_oracle`` moves the replay prediction strictly toward
    the measurement."""
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=256, n_heads=4, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, oracle=MeasuredOracle(_FAST, record=MeasurementLog(_FAST)),
        workload=Workload(tokens_global=256),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: 1.0),
        pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    art = session.export(str(tmp_path / "art"), max_batch=2, max_seq=16)
    assert art.oracle.name == "replay"
    predicted = art.metadata["predicted_step_s"]
    assert predicted is not None

    log = MeasurementLog()
    eng = ServeEngine.from_artifact(art, measurements=log)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    eng.run()
    key = MeasurementLog.step_key(art.measurement_tag, 2, 16)
    measured = log.lookup(key)
    assert measured is not None and measured > 0.0

    orc2 = art.recalibrated_oracle(log)
    clear_tuning_caches()
    pred2 = art.predict_step_s(2, 16, oracle=orc2)
    assert pred2 is not None
    assert abs(pred2 - measured) < abs(predicted - measured)
    # the factor solves fixed + factor*task = measured, so the residual
    # is only re-tuned winner shifts + the unscaled epilogue term
    assert pred2 == pytest.approx(measured, rel=0.1)
    # the recalibrated oracle is its own cache identity
    assert orc2.fingerprint() != art.oracle.fingerprint()

    # a float works too, and non-replay artifacts refuse
    orc3 = art.recalibrated_oracle(measured * 2)
    assert orc3.log.digest() != orc2.log.digest()
    analytic = _cfg()
    s2 = PruningSession(analytic, workload=Workload(tokens_global=256),
                        hooks=TrainHooks(short_term_train=lambda p, s: p,
                                         eval_acc=lambda p, s: 1.0),
                        pcfg=CPruneConfig(a_g=0.0, seq_len=32))
    art2 = s2.export(str(tmp_path / "art2"), max_batch=2, max_seq=16)
    with pytest.raises(ArtifactError, match="replay-backed"):
        art2.recalibrated_oracle(1e-3)
    with pytest.raises(ArtifactError, match="no .* entry"):
        art.recalibrated_oracle(MeasurementLog())

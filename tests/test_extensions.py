"""Beyond-paper extensions (DESIGN.md §7): roofline-guided prune steps,
shard-aware step divisibility."""
import jax
import numpy as np
import pytest

from repro.core import CPrune, CPruneConfig, TrainHooks, Workload
from repro.core.cost_model import Block
from repro.core.program import Program
from repro.core.prune_step import program_prune_step
from repro.core.tuner import tune_gemm


def test_memory_bound_detection():
    # K tiny -> low arithmetic intensity -> memory bound
    mem = Program(m=65536, k=128, n=2048, block=Block(512, 128, 2048),
                  latency=1.0)
    assert mem.memory_bound
    # big K, compute-rich
    comp = Program(m=65536, k=8192, n=8192, block=Block(512, 512, 1024),
                   latency=1.0)
    assert not comp.memory_bound


def test_roofline_guided_step_is_finer_for_memory_bound():
    prog = Program(m=65536, k=128, n=4096, block=Block(512, 128, 2048),
                   latency=1.0)
    assert prog.memory_bound
    base = program_prune_step([(prog, "n")])
    fine = program_prune_step([(prog, "n")], roofline_guided=True)
    assert fine <= base
    assert fine == 128        # lane granularity


def test_roofline_guided_noop_for_compute_bound():
    prog = Program(m=65536, k=8192, n=8192, block=Block(512, 512, 1024),
                   latency=1.0)
    assert not prog.memory_bound
    assert program_prune_step([(prog, "n")], roofline_guided=True) == \
        program_prune_step([(prog, "n")])


def test_cprune_with_roofline_steps_runs():
    from repro.configs import get_reduced_config
    from repro.models.model import init_params, prune_sites

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        d_model=128, d_ff=2048, n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)
    hooks = TrainHooks(short_term_train=lambda p, s: p,
                       eval_acc=lambda p, s: 0.9)
    pcfg = CPruneConfig(a_g=0.1, alpha=0.5, beta=0.99, max_iterations=4,
                        seq_len=64, roofline_steps=True)
    res = CPrune(cfg, sites, Workload(tokens_global=16384), hooks,
                 pcfg).run(params)
    assert res.fps_increase >= 1.0
    assert any(h.accepted for h in res.history)


def test_shard_multiple_keeps_tp_divisibility():
    prog = tune_gemm(65536, 512, 4096)
    for tp in (4, 8, 16):
        step = program_prune_step([(prog, "n")], shard_multiple=tp)
        assert step % tp == 0
        # pruning by multiples of step keeps N divisible by tp
        assert (4096 - step) % tp == 0

"""End-to-end behaviour tests: every assigned architecture runs one
forward/train step on CPU (reduced config) with sane outputs, and the
stateful families decode consistently with the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import layers
from repro.models.model import Model, init_params, make_positions


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD step per arch: shapes ok, no NaNs, loss sane."""
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(metrics["acc"]) <= 1.0
    # loss should be near ln(vocab) at random init
    assert float(metrics["ce"]) < np.log(cfg.vocab_size) + 1.5

    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    new_params = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = jax.jit(model.loss_fn)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "mixtral_8x22b",
                                  "recurrentgemma_9b", "rwkv6_1_6b",
                                  "qwen2_vl_2b", "nemotron_4_15b"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode logits == full forward logits at every position."""
    cfg = get_reduced_config(arch)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S, S0 = 2, 24, 16
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision_patches":
        F = min(cfg.frontend_seq, S // 2)
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(8), (B, F, cfg.d_model), jnp.float32)

    x = model._input_x(params, batch)
    pos = make_positions(cfg, S)
    xb, _ = model.backbone_train(params, x, pos)
    xb = layers.apply_norm(cfg.norm, params["final_norm"], xb)
    ref_logits = model.unembed(params, xb)

    pre_batch = {k: (v[:, :S0] if k == "tokens" else v)
                 for k, v in batch.items()}
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, S))(
        params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(ref_logits[:, S0 - 1]),
                               rtol=2e-4, atol=2e-4)
    dec = jax.jit(model.decode_step)
    for t in range(S0, S - 1):
        logits, caches = dec(params, tokens[:, t:t + 1], caches)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref_logits[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_sliding_window_cache_is_bounded():
    """SWA decode uses a rolling cache of window size, not seq size."""
    cfg = get_reduced_config("mixtral_8x22b")
    model = Model(cfg)
    caches = model.init_caches(batch_size=2, max_seq=1024)
    kv = caches["stack"]["pos0"]
    assert kv.k.shape[2] == cfg.sliding_window  # bounded by window, not 1024


def test_rwkv_state_is_constant_size():
    cfg = get_reduced_config("rwkv6_1_6b")
    model = Model(cfg)
    c_small = model.init_caches(2, 128)
    c_large = model.init_caches(2, 131072)
    assert jax.tree.map(lambda a: a.shape, c_small["stack"]) == \
        jax.tree.map(lambda a: a.shape, c_large["stack"])


def test_training_learns_markov_task():
    """A few dozen steps on the synthetic task must lift accuracy well above
    chance — the signal the CPrune accuracy gates rely on."""
    from repro.data.pipeline import DataPipeline
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=64)
    pipe = DataPipeline(cfg, global_batch=16, seq_len=64)
    tr = Trainer(cfg, TrainerConfig(lr=3e-3, log_every=1000), pipe)
    before = tr.eval_batch()["acc"]
    tr.run(60)
    after = tr.eval_batch()["acc"]
    assert after > before + 0.1, (before, after)
    assert after > 0.3

"""Checkpointing, crash recovery, stragglers, gradient compression."""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (FaultInjector, StragglerMonitor,
                                         compress_grads, decompress_grads,
                                         resilient_loop)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "step": jnp.int32(0)}


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    s = _state()
    ckpt.save(5, s)
    step, restored, _ = ckpt.restore(s)
    assert step == 5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), s, restored)


def test_checkpoint_async_and_retention(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2, async_save=True)
    s = _state()
    for step in (1, 2, 3, 4):
        ckpt.save(step, s)
    ckpt.wait()
    assert ckpt.all_steps() == [3, 4]


def test_crash_mid_save_never_corrupts_latest(tmp_path):
    """A .tmp dir left behind by a crash must be invisible to restore."""
    ckpt = CheckpointManager(tmp_path, async_save=False)
    s = _state()
    ckpt.save(1, s)
    # simulate a crashed save of step 2: partial tmp dir
    tmp = tmp_path / "step_2.tmp"
    tmp.mkdir()
    (tmp / "arr_0.npy").write_bytes(b"garbage")
    assert ckpt.latest_step() == 1
    step, restored, _ = ckpt.restore(s)
    assert step == 1


def test_restore_onto_different_mesh_shardings(tmp_path):
    """Elastic re-mesh: restore with device_put onto new shardings."""
    ckpt = CheckpointManager(tmp_path, async_save=False)
    s = _state()
    ckpt.save(1, s)
    shardings = jax.tree.map(
        lambda a: jax.sharding.SingleDeviceSharding(jax.devices()[0]), s)
    step, restored, _ = ckpt.restore(s, shardings=shardings)
    assert restored["params"]["w"].sharding == shardings["params"]["w"]


def test_resilient_loop_recovers_from_injected_faults(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)
    log = []

    def step_fn(step, state):
        log.append(step)
        return {**state, "step": state["step"] + 1}

    injector = FaultInjector(fail_at_steps=[7, 13])
    state, stats = resilient_loop(
        n_steps=20, state=_state(), step_fn=step_fn, ckpt=ckpt,
        ckpt_every=5, injector=injector)
    assert stats["restarts"] == 2
    assert int(state["step"]) == 20 - 0  # every step eventually ran
    # steps 5..7 were replayed after the first fault (restore to step 5)
    assert log.count(5) >= 1 and log.count(6) >= 2


def test_resilient_loop_raises_after_max_restarts(tmp_path):
    ckpt = CheckpointManager(tmp_path, async_save=False)

    def bad_step(step, state):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        resilient_loop(n_steps=3, state=_state(), step_fn=bad_step,
                       ckpt=ckpt, max_restarts=2)


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(factor=3.0)
    for _ in range(10):
        mon.observe(0.01)
    assert mon.observe(0.2) is True
    assert mon.observe(0.01) is False
    assert mon.stragglers == 1


def test_straggler_monitor_skip_first_discards_warmup():
    """Regression: compile-inflated warmup steps must never seed the
    rolling median. Without skip_first, two 1s compile steps inflate the
    first-5-samples median and a genuinely slow step passes unflagged;
    with skip_first=2 the same trace flags it."""
    compile_steps = [1.0] * 6           # one jit retrace per group shape
    steady = [0.01] * 5
    slow = 0.05                         # 5x steady, but < 3x compile-median

    naive = StragglerMonitor(factor=3.0)
    for t in compile_steps + steady:
        naive.observe(t)
    assert naive.observe(slow) is False         # hidden by warmup samples

    warm = StragglerMonitor(factor=3.0, skip_first=len(compile_steps))
    for t in compile_steps + steady:
        warm.observe(t)
    assert warm.observe(slow) is True
    assert warm.samples == len(steady) + 1      # warmup never recorded
    assert warm.median_s == pytest.approx(0.01)


def test_fault_runtime_is_shared_with_the_serve_stack():
    """The train-loop names re-export repro.util.faults unchanged (the
    serving fleet injects through the same classes)."""
    from repro.util import faults as uf
    assert FaultInjector is uf.FaultInjector
    assert StragglerMonitor is uf.StragglerMonitor
    inj = FaultInjector(specs=[uf.crash_at("decode", 1)])
    inj.fire("decode")
    with pytest.raises(uf.InjectedFault):
        inj.fire("decode")
    # legacy interface still served by the same class
    inj2 = FaultInjector(fail_at_steps=[0])
    with pytest.raises(RuntimeError):
        inj2.maybe_fail(0)


def test_grad_compression_error_feedback_is_unbiased():
    """Sum of decompressed grads + final residual == sum of true grads."""
    key = jax.random.PRNGKey(0)
    grads_seq = [
        {"w": jax.random.normal(jax.random.fold_in(key, i), (32, 32))}
        for i in range(8)
    ]
    residual = None
    total_sent = jnp.zeros((32, 32))
    for g in grads_seq:
        qg, residual = compress_grads(g, residual)
        assert qg["w"]["q"].dtype == jnp.int8
        total_sent = total_sent + decompress_grads(qg)["w"]
    total_true = sum(g["w"] for g in grads_seq)
    # unbiased up to the residual still in flight
    np.testing.assert_allclose(
        np.asarray(total_sent + residual["w"]), np.asarray(total_true),
        rtol=1e-5, atol=1e-5)
    # and the wire format is 4x smaller than fp32
    assert qg["w"]["q"].nbytes * 4 == grads_seq[0]["w"].nbytes


def test_trainer_resumes_deterministically(tmp_path):
    """Train 10 steps straight vs 5 + restart + 5: identical params."""
    from repro.configs import get_reduced_config
    from repro.data.pipeline import DataPipeline
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=32, d_ff=64, vocab_size=64)

    def make(ckpt_dir):
        pipe = DataPipeline(cfg, global_batch=4, seq_len=32)
        return Trainer(cfg, TrainerConfig(
            lr=1e-3, ckpt_dir=ckpt_dir, ckpt_every=5, log_every=100), pipe)

    t1 = make(str(tmp_path / "a"))
    t1.run(10)

    t2 = make(str(tmp_path / "b"))
    t2.run(5)
    t2.ckpt.wait()
    # "crash": rebuild trainer from checkpoint and continue
    t3 = make(str(tmp_path / "b"))
    step, state, _ = t3.ckpt.restore(
        {"params": t3.params, "opt": t3.opt_state})
    t3.params, t3.opt_state = state["params"], state["opt"]
    t3.run(10, start_step=step)

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6),
        t1.params, t3.params)

"""Config integrity: exact published shapes, applicability table, counts."""
import pytest

from repro.configs import (ARCH_IDS, SHAPES, all_configs, get_config,
                           get_reduced_config, shape_applicable)


def test_all_ten_archs_load():
    cfgs = all_configs()
    assert set(cfgs) == set(ARCH_IDS)
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch,expect", [
    ("recurrentgemma_9b", dict(n_layers=38, d_model=4096, n_heads=16,
                               n_kv_heads=1, d_ff=12288, vocab_size=256000)),
    ("mixtral_8x22b", dict(n_layers=56, d_model=6144, n_heads=48,
                           n_kv_heads=8, d_ff=16384, vocab_size=32768,
                           n_experts=8, top_k=2)),
    ("granite_moe_1b_a400m", dict(n_layers=24, d_model=1024, n_heads=16,
                                  n_kv_heads=8, d_ff=512, vocab_size=49155,
                                  n_experts=32, top_k=8)),
    ("nemotron_4_15b", dict(n_layers=32, d_model=6144, n_heads=48,
                            n_kv_heads=8, d_ff=24576, vocab_size=256000,
                            activation="relu2")),
    ("qwen1_5_110b", dict(n_layers=80, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=49152, vocab_size=152064,
                          qkv_bias=True)),
    ("qwen3_1_7b", dict(n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
                        d_ff=6144, vocab_size=151936, qk_norm=True)),
    ("internlm2_20b", dict(n_layers=48, d_model=6144, n_heads=48,
                           n_kv_heads=8, d_ff=16384, vocab_size=92544)),
    ("rwkv6_1_6b", dict(n_layers=24, d_model=2048, d_ff=7168,
                        vocab_size=65536)),
    ("hubert_xlarge", dict(n_layers=48, d_model=1280, n_heads=16,
                           n_kv_heads=16, d_ff=5120, vocab_size=504,
                           causal=False)),
    ("qwen2_vl_2b", dict(n_layers=28, d_model=1536, n_heads=12,
                         n_kv_heads=2, d_ff=8960, vocab_size=151936,
                         rope="mrope")),
])
def test_published_shapes(arch, expect):
    cfg = get_config(arch)
    for k, v in expect.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch,lo,hi", [
    ("recurrentgemma_9b", 7e9, 11e9),
    ("mixtral_8x22b", 120e9, 160e9),
    ("granite_moe_1b_a400m", 0.9e9, 1.8e9),
    ("nemotron_4_15b", 12e9, 19e9),
    ("qwen1_5_110b", 95e9, 125e9),
    ("qwen3_1_7b", 1.3e9, 2.4e9),
    ("internlm2_20b", 17e9, 24e9),
    ("rwkv6_1_6b", 1.2e9, 2.2e9),
    ("hubert_xlarge", 0.7e9, 1.3e9),
    ("qwen2_vl_2b", 1.2e9, 2.2e9),
])
def test_param_counts_in_published_range(arch, lo, hi):
    n = get_config(arch).param_count()
    assert lo <= n <= hi, (arch, n / 1e9)


def test_moe_active_params_below_total():
    cfg = get_config("mixtral_8x22b")
    assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_applicability_matrix():
    skips = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            if not ok:
                skips.append((a, s.name))
    # hubert: decode+long; 6 full-attention archs: long
    assert ("hubert_xlarge", "decode_32k") in skips
    assert ("hubert_xlarge", "long_500k") in skips
    assert ("qwen1_5_110b", "long_500k") in skips
    assert ("rwkv6_1_6b", "long_500k") not in [tuple(x) for x in skips]
    assert ("mixtral_8x22b", "long_500k") not in [tuple(x) for x in skips]
    assert len(skips) == 8


def test_reduced_configs_are_small_and_same_family():
    for a in ARCH_IDS:
        full, red = get_config(a), get_reduced_config(a)
        assert red.param_count() < full.param_count() / 100
        assert red.family == full.family
        assert red.block_pattern == full.block_pattern
        assert (red.n_experts > 0) == (full.n_experts > 0)

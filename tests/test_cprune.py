"""CPrune core: task table, ordering, prune step, Algorithm 1 mechanics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs import get_reduced_config
from repro.core import (CPrune, CPruneConfig, TrainHooks, Workload,
                        build_tuned_table)
from repro.core.applier import apply_keep, prune_site_by_rank
from repro.core.latency import model_latency
from repro.core.program import Iterator
from repro.core.prune_step import lcm_prune_step
from repro.core.ranking import keep_indices, rank_units
from repro.core.tuner import TunerStats, tune_gemm, untuned_gemm
from repro.models.model import Model, init_params, prune_sites


def _setup(arch="qwen3_1_7b", **over):
    cfg = get_reduced_config(arch).with_overrides(**over)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)
    return cfg, model, params, sites


# ---------------------------------------------------------------------------
# Paper §3.5 worked example
# ---------------------------------------------------------------------------

def test_lcm_formula_matches_paper_example():
    fast = [Iterator("ff", (4, 8, 16), (True,) * 3),
            Iterator("ax3", (4, 8, 16), (True,) * 3)]
    slow = [Iterator("ff", (4, 128), (True, True)),
            Iterator("ax3", (512, 1), (True, True))]
    assert lcm_prune_step(fast) == 32   # paper: LCM(32, 32) = 32
    assert lcm_prune_step(slow) == 4    # paper: LCM(4, 1) = 4


def test_prune_step_respects_shard_multiple():
    its = [Iterator("n", (4, 2, 128), (True, True, False))]
    assert lcm_prune_step(its, shard_multiple=16) % 16 == 0


# ---------------------------------------------------------------------------
# Task table (§3.3, §3.4)
# ---------------------------------------------------------------------------

def test_task_groups_identical_subgraphs():
    """RecurrentGemma: FFN shapes identical across rglru and attn blocks ->
    one FFN task whose subgraph count spans the stacks (paper Fig. 4)."""
    cfg, model, params, sites = _setup("recurrentgemma_9b")
    wl = Workload(tokens_global=1024)
    table = build_tuned_table(sites, wl)
    ffn_tasks = [t for t in table.tasks if t.sites[0].kind == "ffn"]
    assert len(ffn_tasks) == 1
    assert ffn_tasks[0].n_subgraphs == sum(
        s.multiplicity for s in sites if s.kind == "ffn")
    assert len(ffn_tasks[0].sites) >= 2   # spans >1 stack position


def test_task_ordering_by_pruning_impact():
    cfg, model, params, sites = _setup()
    table = build_tuned_table(sites, Workload(tokens_global=2048))
    ordered = table.ordered()
    impacts = [t.pruning_impact for t in ordered]
    assert impacts == sorted(impacts, reverse=True)
    assert ordered[0].pruning_impact == max(impacts)


def test_tuned_never_slower_than_untuned():
    stats = TunerStats()
    for (m, k, n) in [(512, 256, 1024), (128, 4096, 512), (64, 64, 64)]:
        tuned = tune_gemm(m, k, n, stats=stats)
        naive = untuned_gemm(m, k, n)
        assert tuned.latency <= naive.latency + 1e-12
    assert stats.candidates_evaluated > 0


# ---------------------------------------------------------------------------
# Applier: functional pruning
# ---------------------------------------------------------------------------

def test_pruning_zero_channels_preserves_function():
    """Zero out d_ff channels, then prune exactly those channels: the model
    function must be unchanged (proves the applier slices the right,
    *coupled* axes)."""
    cfg, model, params, sites = _setup()
    site = next(s for s in sites if s.kind == "ffn")
    batch = make_batch(cfg)
    # zero the channels we will prune (lowest L1 = the zeroed ones)
    drop = np.arange(0, site.dim, 2)    # half the channels
    for rel_path, axis in site.param_axes:
        node = params
        for part in (site.block_path + "/" + rel_path).split("/")[:-1]:
            node = node[part]
        leaf = (site.block_path + "/" + rel_path).split("/")[-1]
        arr = np.array(node[leaf])   # writable copy
        ax = axis + 1  # stacked
        sl = [slice(None)] * arr.ndim
        sl[ax] = drop
        arr[tuple(sl)] = 0.0
        node[leaf] = jnp.asarray(arr)

    loss_before, _ = jax.jit(model.loss_fn)(params, batch)
    scores = rank_units(params, site, "l1")
    new_params, new_site = prune_site_by_rank(params, site, len(drop), scores)
    assert new_site.dim == site.dim - len(drop)
    loss_after, _ = jax.jit(model.loss_fn)(new_params, batch)
    np.testing.assert_allclose(float(loss_before), float(loss_after),
                               rtol=1e-5)


def test_heads_pruning_keeps_gqa_grouping():
    cfg, model, params, sites = _setup(n_heads=8, n_kv_heads=2, head_dim=16)
    site = next(s for s in sites if s.kind == "heads")
    assert site.granularity == 2
    scores = rank_units(params, site, "l1")
    new_params, new_site = prune_site_by_rank(params, site, 2, scores)
    wq = new_params["stack"]["pos0"]["mixer"]["wq"]
    assert wq.shape[2] == 6           # (L, d, H=6, hd)
    # model still runs with 3 q-heads per kv group
    loss, _ = jax.jit(model.loss_fn)(new_params, make_batch(cfg))
    assert np.isfinite(float(loss))


def test_expert_pruning_runs():
    cfg, model, params, sites = _setup("mixtral_8x22b")
    site = next(s for s in sites if s.kind == "experts")
    scores = rank_units(params, site, "l1")
    new_params, new_site = prune_site_by_rank(params, site, 1, scores)
    assert new_params["stack"]["pos0"]["ffn"]["router"].shape[-1] == \
        cfg.n_experts - 1
    loss, _ = jax.jit(model.loss_fn)(new_params, make_batch(cfg))
    assert np.isfinite(float(loss))


def test_keep_indices_grouped():
    scores = np.array([5.0, 1.0, 4.0, 9.0, 0.5, 7.0, 2.0, 3.0])
    keep = keep_indices(scores, 2, group=2)   # drop 1 per contiguous half
    assert len(keep) == 6
    assert 1 not in keep and 4 not in keep    # lowest in each half


# ---------------------------------------------------------------------------
# Algorithm 1 mechanics
# ---------------------------------------------------------------------------

def _fake_hooks(acc_sequence):
    """eval returns successive values from acc_sequence (then repeats last)."""
    state = {"i": -1}

    def eval_acc(params, sites):
        state["i"] = min(state["i"] + 1, len(acc_sequence) - 1)
        return acc_sequence[state["i"]]

    return TrainHooks(short_term_train=lambda p, s: p, eval_acc=eval_acc)


def test_cprune_accepts_until_accuracy_gate():
    # compute-dominated dims so pruning actually moves the cost model
    cfg, model, params, sites = _setup(d_model=128, d_ff=2048, n_layers=4)
    wl = Workload(tokens_global=16384)
    # acc: init 0.9, first candidate ok (0.89), second fails hard (0.2)
    hooks = _fake_hooks([0.9, 0.89, 0.2, 0.2, 0.2, 0.2])
    pcfg = CPruneConfig(a_g=0.5, alpha=0.95, beta=0.99, max_iterations=10,
                        seq_len=64)
    res = CPrune(cfg, sites, wl, hooks, pcfg).run(params)
    accepted = [h for h in res.history if h.accepted]
    rejected = [h for h in res.history if not h.accepted]
    assert len(accepted) >= 1
    assert res.fps_increase > 1.0
    # the accuracy-failed task must have been retired (appears once)
    if rejected:
        kinds = [h.task_id for h in rejected]
        assert len(kinds) == len(set(kinds))


def test_cprune_latency_monotone_over_accepted_iterations():
    cfg, model, params, sites = _setup(d_model=128, d_ff=2048, n_layers=4)
    wl = Workload(tokens_global=16384)
    hooks = _fake_hooks([0.9] * 50)   # accuracy never blocks
    pcfg = CPruneConfig(a_g=0.1, alpha=0.5, beta=0.99, max_iterations=8,
                        seq_len=64)
    res = CPrune(cfg, sites, wl, hooks, pcfg).run(params)
    lms = [h.l_m for h in res.history if h.accepted]
    assert len(lms) >= 2
    assert all(b < a for a, b in zip(lms, lms[1:]))
    # pruned dims shrank
    assert any(s.dim < 2048 for s in res.sites if s.kind == "ffn")


def test_cprune_real_model_prunes_and_still_trains():
    """Full loop against the real JAX model with real (tiny) training."""
    cfg, model, params, sites = _setup(d_ff=256, n_layers=2, vocab_size=64)
    from repro.data.pipeline import DataPipeline
    pipe = DataPipeline(cfg, global_batch=8, seq_len=32)
    val = pipe.batch(10 ** 6)
    jloss = jax.jit(model.loss_fn)
    jgrad = jax.jit(jax.value_and_grad(lambda p, b: model.loss_fn(p, b)[0]))

    def short_train(p, sites):
        for i in range(2):
            _, g = jgrad(p, pipe.batch(i))
            p = jax.tree.map(lambda a, b: a - 0.01 * b.astype(a.dtype), p, g)
        return p

    def eval_acc(p, sites):
        _, m = jloss(p, val)
        return float(jnp.exp(-m["ce"]))

    hooks = TrainHooks(short_term_train=short_train, eval_acc=eval_acc)
    pcfg = CPruneConfig(a_g=1e-4, alpha=0.5, beta=0.999, max_iterations=3,
                        seq_len=32)
    res = CPrune(cfg, sites, Workload(tokens_global=256), hooks, pcfg).run(
        params)
    assert res.fps_increase >= 1.0
    loss, _ = jloss(res.params, val)
    assert np.isfinite(float(loss))

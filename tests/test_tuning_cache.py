"""Tuning engine: vectorized grid search, ProgramCache, incremental retune.

Covers the cache-correctness contract: cached/warm tuning is bit-identical
to cold tuning, the vectorized engine is bit-identical to the scalar
reference engine, incremental table retuning matches a from-scratch
rebuild, and CPrune's per-iteration tuning work collapses once the cache
is active.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_reduced_config
from repro.core import cost_model, latency, tuner, tuning_cache
from repro.core.cprune import CPrune, CPruneConfig, TrainHooks
from repro.core.tasks import TaskTable, Workload
from repro.core.tuner import TunerStats, build_tuned_table, tune_gemm
from repro.models.model import init_params, prune_sites


from repro.core import clear_tuning_caches


@pytest.fixture(autouse=True)
def _cold_caches():
    clear_tuning_caches()
    yield
    clear_tuning_caches()


# ---------------------------------------------------------------------------
# Engine equivalence + cache correctness
# ---------------------------------------------------------------------------

_CASES = [
    (65536, 256, 8192, 1, 2, 4),
    (65536, 8192, 256, 1, 2, 0),
    (512, 256, 1024, 1, 4, 0),
    (64, 64, 64, 1, 2, 0),
    (128, 4096, 512, 8, 2, 6),
    (1, 128, 128, 1, 2, 0),
]


def test_vectorized_matches_reference_bit_identical():
    for (m, k, n, b, db, epi) in _CASES:
        with tuner.engine_mode("reference"):
            ref = tune_gemm(m, k, n, batch=b, dtype_bytes=db,
                            epilogue_ops=epi)
        new = tune_gemm(m, k, n, batch=b, dtype_bytes=db, epilogue_ops=epi)
        assert ref == new          # same Block AND exact same latency float


def test_cost_grid_matches_scalar_cost():
    m, k, n = 1024, 512, 768
    bm, bk, bn = tuner.candidate_grid(m, k, n)
    lats = cost_model.matmul_cost_grid(m, k, n, bm, bk, bn,
                                       dtype_bytes=2, batch=3,
                                       epilogue_ops=5)
    for i in range(len(bm)):
        blk = cost_model.Block(int(bm[i]), int(bk[i]), int(bn[i]))
        assert lats[i] == cost_model.matmul_cost(
            m, k, n, blk, dtype_bytes=2, batch=3, epilogue_ops=5)


def test_cold_tune_is_grid_exact_and_warm_is_free():
    stats = TunerStats()
    p1 = tune_gemm(2048, 512, 1024, stats=stats)
    grid = len(tuner.candidate_blocks(2048, 512, 1024))
    assert stats.candidates_evaluated == grid
    assert stats.cache_misses == 1 and stats.cache_hits == 0
    p2 = tune_gemm(2048, 512, 1024, stats=stats)
    assert stats.candidates_evaluated == grid     # no new evaluations
    assert stats.cache_hits == 1
    assert p1 == p2                               # bit-identical Program


def test_json_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "tuning_log.json")
    stats = TunerStats()
    p1 = tune_gemm(4096, 1024, 2048, stats=stats, epilogue_ops=3)
    assert tuning_cache.global_cache().save(path) >= 1

    tuning_cache.reset_global_cache()
    assert tuning_cache.global_cache().load(path) >= 1
    stats2 = TunerStats()
    p2 = tune_gemm(4096, 1024, 2048, stats=stats2, epilogue_ops=3)
    assert stats2.candidates_evaluated == 0 and stats2.cache_hits == 1
    assert p1 == p2


def test_target_constant_swap_invalidates_cache():
    stats = TunerStats()
    tune_gemm(512, 512, 512, stats=stats)
    old = cost_model.HBM_BW
    cost_model.HBM_BW = 2 * old
    try:
        tune_gemm(512, 512, 512, stats=stats)
    finally:
        cost_model.HBM_BW = old
    assert stats.cache_misses == 2 and stats.cache_hits == 0
    # back on the original target: the first entry is valid again
    tune_gemm(512, 512, 512, stats=stats)
    assert stats.cache_hits == 1


def test_vmem_override_constrains_search():
    small = 1 * 1024 * 1024
    for blk in tuner.candidate_blocks(65536, 1024, 2048, vmem=small):
        assert blk.vmem_bytes(2) <= small
    p_small = tune_gemm(65536, 1024, 2048, vmem=small)
    p_big = tune_gemm(65536, 1024, 2048)
    assert p_small.block.vmem_bytes(2) <= small
    assert p_big.block.vmem_bytes(2) > small      # override actually binds
    assert p_small.latency >= p_big.latency


# ---------------------------------------------------------------------------
# Engine-mode guard rails + public grid-cache reset
# ---------------------------------------------------------------------------

def test_engine_mode_rejects_unknown_and_restores_on_exception():
    before = tuner.engine()
    with pytest.raises(ValueError, match="unknown tuning engine mode"):
        with tuner.engine_mode("no_such_engine"):
            pass                                  # pragma: no cover
    assert tuner.engine() == before               # rejected before mutation
    with pytest.raises(RuntimeError):
        with tuner.engine_mode("reference"):
            assert tuner.engine() == "reference"
            raise RuntimeError("body blew up")
    assert tuner.engine() == before               # restored on exception
    # nested modes unwind in order
    with tuner.engine_mode("reference"):
        with tuner.engine_mode("vectorized"):
            assert tuner.engine() == "vectorized"
        assert tuner.engine() == "reference"
    assert tuner.engine() == before


def test_clear_grid_cache_public_api():
    tuner.candidate_grid(512, 512, 512)
    assert len(tuner._GRID_CACHE) > 0
    tuner.clear_grid_cache()
    assert len(tuner._GRID_CACHE) == 0
    # clear_tuning_caches goes through the public entry point too
    tuner.candidate_grid(512, 512, 512)
    clear_tuning_caches()
    assert len(tuner._GRID_CACHE) == 0


# ---------------------------------------------------------------------------
# Incremental TaskTable retuning
# ---------------------------------------------------------------------------

def _sites_and_wl():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        d_model=128, d_ff=2048, n_layers=2)
    return cfg, prune_sites(cfg), Workload(tokens_global=4096)


def test_incremental_retune_matches_scratch_rebuild():
    cfg, sites, wl = _sites_and_wl()
    table = build_tuned_table(sites, wl)

    pruned = [s.with_dim(s.dim - 128) if s.kind == "ffn" else s
              for s in sites]
    s_inc = TunerStats()
    inc = build_tuned_table(pruned, wl, stats=s_inc, prev=table)
    assert s_inc.tasks_reused >= 1           # heads task carried over

    tuning_cache.reset_global_cache()        # scratch build is truly cold
    scratch = build_tuned_table(pruned, wl)
    assert len(inc.tasks) == len(scratch.tasks)
    for a, b in zip(inc.tasks, scratch.tasks):
        assert a.signature == b.signature
        assert a.programs == b.programs      # bit-identical programs
        assert a.latency == b.latency


def test_incremental_retune_refuses_stale_prev():
    """A prev table tuned under another target/workload must not carry."""
    cfg, sites, wl = _sites_and_wl()
    table = build_tuned_table(sites, wl)
    old = cost_model.HBM_BW
    cost_model.HBM_BW = 2 * old
    try:
        stats = TunerStats()
        swapped = build_tuned_table(sites, wl, stats=stats, prev=table)
        assert stats.tasks_reused == 0       # fingerprint mismatch
        fresh = build_tuned_table(sites, wl)
        for a, b in zip(swapped.tasks, fresh.tasks):
            assert a.programs == b.programs
    finally:
        cost_model.HBM_BW = old
    # different workload sharding: signature matches but programs don't
    stats = TunerStats()
    build_tuned_table(sites, Workload(tokens_global=4096, tp=2),
                      stats=stats, prev=table)
    assert stats.tasks_reused == 0


def test_task_for_site_index():
    cfg, sites, wl = _sites_and_wl()
    table = TaskTable(sites, wl)
    for s in sites:
        t = table.task_for_site(s.site_id)
        assert t is not None and any(x.site_id == s.site_id for x in t.sites)
    assert table.task_for_site("no/such:site") is None
    for t in table.tasks:
        assert table.task_by_signature(t.signature) is t


# ---------------------------------------------------------------------------
# fixed_latency memoization
# ---------------------------------------------------------------------------

def test_fixed_latency_memoized_by_head_dims():
    cfg, sites, wl = _sites_and_wl()
    stats = TunerStats()
    t1, bd1 = latency.fixed_latency(cfg, sites, wl, seq_len=64, stats=stats)
    work = stats.candidates_evaluated
    assert work > 0
    t2, bd2 = latency.fixed_latency(cfg, sites, wl, seq_len=64, stats=stats)
    assert stats.candidates_evaluated == work    # served from the memo
    assert t1 == t2 and bd1 == bd2
    bd2["unembed"] = 0.0                         # memo hands out copies
    _, bd3 = latency.fixed_latency(cfg, sites, wl, seq_len=64, stats=stats)
    assert bd3 == bd1
    # pruning q-heads changes the fixed half -> recompute, new total
    pruned = [s.with_dim(s.dim - s.granularity) if s.kind == "heads" else s
              for s in sites]
    t3, _ = latency.fixed_latency(cfg, pruned, wl, seq_len=64, stats=stats)
    assert t3 != t1


# ---------------------------------------------------------------------------
# CPrune regression: tuning work collapses after the cold start
# ---------------------------------------------------------------------------

class _RecordingCPrune(CPrune):
    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.deltas = []

    def _tuned_table(self, sites, prev=None):
        before = self.stats.candidates_evaluated
        table = super()._tuned_table(sites, prev)
        self.deltas.append(self.stats.candidates_evaluated - before)
        return table


def _fake_hooks(acc=0.9):
    return TrainHooks(short_term_train=lambda p, s: p,
                      eval_acc=lambda p, s: acc)


def test_cprune_candidates_evaluated_drops_across_iterations():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        d_model=128, d_ff=2048, n_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    sites = prune_sites(cfg)
    pcfg = CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999, max_iterations=4,
                        seq_len=64)
    cp = _RecordingCPrune(cfg, sites, Workload(tokens_global=16384),
                          _fake_hooks(), pcfg)
    res = cp.run(params)
    assert sum(h.accepted for h in res.history) >= 2
    cold, warm = cp.deltas[0], cp.deltas[1:]
    assert cold > 0 and warm
    # every candidate retune after the cold start does strictly less work:
    # unchanged tasks carry over, unchanged GEMMs hit the ProgramCache
    assert all(d < cold for d in warm)
    assert res.tuner_stats.cache_hits > 0
    assert res.tuner_stats.tasks_reused >= len(warm)
    # warm re-tune of an unchanged model does no grid work at all
    stats = TunerStats()
    build_tuned_table(res.sites, cp.wl, stats=stats, prev=None)
    assert stats.candidates_evaluated == 0       # every GEMM already cached


def test_engines_agree_on_cprune_history():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        d_model=128, d_ff=1024, n_layers=2)
    pcfg = CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999, max_iterations=3,
                        seq_len=64)
    wl = Workload(tokens_global=16384)
    sites = prune_sites(cfg)

    def history(engine):
        tuning_cache.reset_global_cache()
        latency.clear_fixed_latency_cache()
        params = init_params(jax.random.PRNGKey(0), cfg)
        with tuner.engine_mode(engine):
            res = CPrune(cfg, sites, wl, _fake_hooks(), pcfg).run(params)
        return [(h.task_kind, h.prune_units, h.dim_before, h.dim_after,
                 h.l_m, h.accepted) for h in res.history]

    assert history("reference") == history("vectorized")

"""Serving engine: correctness of batched greedy decode + scheduler."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.model import Model, init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.scheduler import Scheduler, SchedulerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    """Reference: full forward re-run per generated token."""
    model = Model(cfg)
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(np.array(toks, np.int32))[None]}
        x = model._input_x(params, batch)
        from repro.models.model import make_positions
        from repro.models import layers
        pos = make_positions(cfg, len(toks))
        xb, _ = model.backbone_train(params, x, pos)
        xb = layers.apply_norm(cfg.norm, params["final_norm"], xb)
        logits = model.unembed(params, xb[:, -1])
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_greedy_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    n_new = 6
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=8 + n_new)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    eng.run()
    got = eng.done[0].output
    expect = _greedy_reference(cfg, params, prompt, n_new)
    assert got == expect


def test_engine_reports_predicted_vs_measured_step(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      predicted_step_s=1.5e-3)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    stats = eng.run()
    assert stats["decode_steps"] == 3          # 4 tokens = 1 sampled + 3 steps
    assert stats["measured_step_s"] > 0.0
    assert stats["predicted_step_s"] == 1.5e-3
    expect = (1.5e-3 - stats["measured_step_s"]) / stats["measured_step_s"]
    assert stats["oracle_rel_error"] == pytest.approx(expect)
    # without a prediction the error key is absent, not None/garbage
    eng2 = ServeEngine(cfg, params, max_batch=2, max_seq=16)
    eng2.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=2))
    stats2 = eng2.run()
    assert stats2["predicted_step_s"] is None
    assert "oracle_rel_error" not in stats2


def test_engine_reports_latency_percentiles(setup):
    """p50/p95 TTFT, per-request decode latency, and per-step percentiles
    — the serve-time check for the planner's latency claims."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    stats = eng.run()
    assert 0.0 < stats["p50_ttft_s"] <= stats["p95_ttft_s"]
    assert 0.0 <= stats["p50_decode_s"] <= stats["p95_decode_s"]
    assert 0.0 < stats["p50_step_s"] <= stats["p95_step_s"]
    # percentiles summarize the same samples the aggregates come from
    assert stats["p50_ttft_s"] <= max(
        r.t_first_token - r.t_submit for r in eng.done)
    assert stats["p95_step_s"] <= stats["decode_steps"] * stats[
        "measured_step_s"] + 1e-9
    # an idle engine reports zeroed percentiles, not NaN/crash
    empty = ServeEngine(cfg, params, max_batch=2, max_seq=24).run()
    for k in ("p50_ttft_s", "p95_ttft_s", "p50_decode_s", "p95_decode_s",
              "p50_step_s", "p95_step_s"):
        assert empty[k] == 0.0


def test_engine_batches_multiple_requests(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=24)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(6)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    stats = eng.run()
    assert stats["requests"] == 6
    assert stats["waves"] == 2          # 4 + 2 with max_batch=4
    assert stats["total_new_tokens"] == 24
    # batching must not cross-contaminate: request 0 alone == in batch
    solo = ServeEngine(cfg, params, max_batch=1, max_seq=24)
    solo.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
    solo.run()
    batched_r0 = next(r for r in eng.done if r.rid == 0)
    assert solo.done[0].output == batched_r0.output


def test_engine_mixed_length_prompts_wave_correctly(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    eng = ServeEngine(cfg, params, max_batch=8, max_seq=32)
    for i, L in enumerate((8, 8, 12, 12, 8)):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=L).astype(np.int32), max_new_tokens=2))
    stats = eng.run()
    assert stats["requests"] == 5
    assert stats["waves"] >= 2          # length groups cannot share a wave


def test_engine_serves_real_pruned_params_end_to_end():
    """Prune via the session front door, then serve the *pruned* params:
    decode outputs keep their shapes and the batch accounting adds up."""
    from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, workload=Workload(tokens_global=8192),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: 0.9),
        pcfg=CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999,
                          max_iterations=2, seq_len=64))
    res = session.prune(strategy="cprune")
    assert any(h.accepted for h in res.history)
    ffn = next(s for s in res.sites if s.kind == "ffn")
    assert ffn.dim < cfg.d_ff                     # params really shrank
    assert res.params["stack"]["pos0"]["ffn"]["w_up"].shape[-1] == ffn.dim

    eng = session.serve(max_batch=4, max_seq=24)
    rng = np.random.default_rng(3)
    n_req, n_new = 6, 4
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=n_new))
    stats = eng.run()
    # batch accounting: every request finished with exactly its token budget
    assert stats["requests"] == n_req
    assert stats["waves"] == 2                    # 4 + 2 with max_batch=4
    assert stats["total_new_tokens"] == n_req * n_new
    for r in eng.done:
        assert r.done and len(r.output) == n_new
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    # pruned-model decode must match its own full-forward reference
    r0 = next(r for r in eng.done if r.rid == 0)
    expect = _greedy_reference(cfg, res.params, r0.prompt, n_new)
    assert r0.output == expect


# ---------------------------------------------------------------------------
# Scheduler core (ISSUE 5): bucketed admission, slot compaction, step API
# ---------------------------------------------------------------------------

def _mk(rng, cfg, rid, plen, n_new):
    return Request(rid=rid, prompt=rng.integers(
        0, cfg.vocab_size, size=plen).astype(np.int32),
        max_new_tokens=n_new)


def test_scheduler_buckets_by_prompt_length_and_groups_decode_lengths():
    """Pure policy: interleaved lengths land in per-length buckets, the
    fullest bucket is admitted first, and a bucket's admission slice
    groups similar max_new_tokens so the cohort finishes together."""
    sched = Scheduler(SchedulerConfig())
    rng = np.random.default_rng(0)
    cfg = get_reduced_config("qwen3_1_7b")
    reqs = []
    for i in range(8):
        r = _mk(rng, cfg, i, 8 if i % 2 == 0 else 12,
                4 if i % 4 < 2 else 16)
        reqs.append(r)
        sched.submit(r)
    assert len(sched) == 8
    batch = sched.select(4)
    # one prompt-length bucket, grouped by decode length
    assert len(batch) == 4
    assert len({len(r.prompt) for r in batch}) == 1
    assert [r.max_new_tokens for r in batch] == sorted(
        r.max_new_tokens for r in batch)
    assert len(sched) == 4
    # the other bucket comes next; wave policy refuses mid-decode admission
    batch2 = sched.select(4)
    assert len(batch2) == 4
    assert len({len(r.prompt) for r in batch2}) == 1
    assert len(batch[0].prompt) != len(batch2[0].prompt)
    wave = Scheduler(SchedulerConfig(policy="wave"))
    wave.submit(_mk(rng, cfg, 99, 8, 4))
    assert wave.select(4, live_groups=1) == []
    assert len(wave.select(4, live_groups=0)) == 1


def test_engine_stops_stepping_finished_slots_on_mixed_max_new(setup):
    """Satellite: a wave used to run max(max_new_tokens) full-width steps.
    The scheduler core compacts finished slots away, so mixed decode
    budgets stop paying for the longest request — outputs unchanged."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(4)]
    budgets = [8, 2, 2, 2]

    def drain(policy):
        eng = ServeEngine(cfg, params, max_batch=4, max_seq=24,
                          scheduler=policy)
        for i, (p, n) in enumerate(zip(prompts, budgets)):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        return eng, eng.run()

    legacy, legacy_stats = drain("wave")
    new, new_stats = drain("bucketed")
    # legacy: 4 slots x (8 - 1) decode steps, finished or not
    assert legacy_stats["slot_steps"] == 4 * 7
    # scheduler core: the three short requests leave after their second
    # token; the rest of the drain is a compacted batch
    assert new_stats["slot_steps"] < legacy_stats["slot_steps"]
    assert new_stats["active_slot_steps"] <= new_stats["slot_steps"]
    assert new_stats["total_new_tokens"] == legacy_stats[
        "total_new_tokens"] == sum(budgets)
    # greedy outputs are bit-identical across policies, per request
    for rid in range(4):
        a = next(r for r in legacy.done if r.rid == rid)
        b = next(r for r in new.done if r.rid == rid)
        assert a.output == b.output


def test_engine_keeps_batches_full_on_interleaved_prompt_lengths(setup):
    """Satellite: alternating prompt lengths must not collapse batch
    occupancy — length bucketing admits full same-length cohorts."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=32)
    for i in range(8):
        eng.submit(_mk(rng, cfg, i, 8 if i % 2 == 0 else 12, 4))
    stats = eng.run()
    assert stats["requests"] == 8
    # every admitted cohort was a full batch of one prompt length
    assert stats["waves"] == 2
    assert stats["mean_batch_occupancy"] == pytest.approx(1.0)
    assert stats["slot_steps"] == stats["active_slot_steps"]


def test_engine_step_api_is_non_blocking_and_resumable(setup):
    cfg, params = setup
    rng = np.random.default_rng(13)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24)
    for i in range(3):
        eng.submit(_mk(rng, cfg, i, 8, 3))
    assert eng.has_work
    # a zero deadline does no work and loses nothing
    stats0 = eng.serve_forever(deadline_s=0.0)
    assert stats0["requests"] == 0 and len(eng.pending) == 3
    # first quantum admits (prefill), later quanta decode, idle when done
    ev = eng.step()
    assert ev["event"] == "prefill" and ev["admitted"] == 2
    seen = {ev["event"]}
    while eng.has_work:
        seen.add(eng.step()["event"])
    assert seen == {"prefill", "decode"}
    assert eng.step()["event"] == "idle"
    assert len(eng.done) == 3
    # run() on the drained engine is a no-op, not an error
    assert eng.run()["requests"] == 3


def test_engine_empty_run_returns_zeroed_stats(setup):
    """Satellite: run() on an empty queue yields zeroed, finite stats —
    never NaN — and no oracle-error key."""
    cfg, params = setup
    stats = ServeEngine(cfg, params, max_batch=2, max_seq=24).run()
    assert stats["requests"] == 0
    assert "oracle_rel_error" not in stats
    for k, v in stats.items():
        if isinstance(v, float):
            assert math.isfinite(v), f"{k} is not finite: {v}"
            assert v == 0.0 or k == "wall_s", f"{k} nonzero on empty run"
    assert stats["predicted_step_s"] is None
    assert stats["tokens_per_s"] == 0.0
    assert stats["mean_batch_occupancy"] == 0.0


def test_engine_records_decode_step_into_measurement_log(setup):
    from repro.core.oracle import MeasurementLog

    cfg, params = setup
    rng = np.random.default_rng(14)
    log = MeasurementLog()
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24,
                      measurements=log)
    eng.submit(_mk(rng, cfg, 0, 8, 4))
    stats = eng.run()
    key = MeasurementLog.step_key(cfg.name, 2, 24)
    assert log.lookup(key) is not None and log.lookup(key) > 0.0
    # the recorded value summarizes the same samples stats() reports
    assert log.lookup(key) <= stats["p95_step_s"] + 1e-12
    # an idle engine records nothing rather than garbage
    eng2 = ServeEngine(cfg, params, max_batch=2, max_seq=24)
    assert eng2.record_measurements(MeasurementLog()) is None
    with pytest.raises(ValueError, match="MeasurementLog"):
        eng2.record_measurements()


def test_engine_admits_next_cohort_mid_decode(setup):
    """Continuous batching at group granularity: slots freed by finished
    requests are refilled by a new cohort before the first finishes."""
    cfg, params = setup
    rng = np.random.default_rng(15)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=32,
                      scheduler=SchedulerConfig(compact="exact"))
    # cohort 1: one long, three short -> three slots free mid-decode
    for i, n in enumerate((12, 2, 2, 2)):
        eng.submit(_mk(rng, cfg, i, 8, n))
    # cohort 2 waits in another length bucket
    for i in range(4, 7):
        eng.submit(_mk(rng, cfg, i, 10, 2))
    events = []
    while eng.has_work:
        ev = eng.step()
        events.append((ev["event"], len(eng.done)))
    # the second prefill happened while the long request was still
    # decoding (fewer than all 7 requests were done at that point)
    prefill_points = [done for e, done in events if e == "prefill"]
    assert len(prefill_points) == 2
    assert prefill_points[1] < 7
    assert next(r for r in eng.done if r.rid == 0).output and \
        len(eng.done) == 7

"""Serving engine: correctness of batched greedy decode + scheduler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models.model import Model, init_params
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, vocab_size=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_reference(cfg, params, prompt, n_new):
    """Reference: full forward re-run per generated token."""
    model = Model(cfg)
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(np.array(toks, np.int32))[None]}
        x = model._input_x(params, batch)
        from repro.models.model import make_positions
        from repro.models import layers
        pos = make_positions(cfg, len(toks))
        xb, _ = model.backbone_train(params, x, pos)
        xb = layers.apply_norm(cfg.norm, params["final_norm"], xb)
        logits = model.unembed(params, xb[:, -1])
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_greedy_matches_reference(setup):
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    n_new = 6
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=8 + n_new)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    eng.run()
    got = eng.done[0].output
    expect = _greedy_reference(cfg, params, prompt, n_new)
    assert got == expect


def test_engine_reports_predicted_vs_measured_step(setup):
    cfg, params = setup
    rng = np.random.default_rng(3)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=16,
                      predicted_step_s=1.5e-3)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    stats = eng.run()
    assert stats["decode_steps"] == 3          # 4 tokens = 1 sampled + 3 steps
    assert stats["measured_step_s"] > 0.0
    assert stats["predicted_step_s"] == 1.5e-3
    expect = (1.5e-3 - stats["measured_step_s"]) / stats["measured_step_s"]
    assert stats["oracle_rel_error"] == pytest.approx(expect)
    # without a prediction the error key is absent, not None/garbage
    eng2 = ServeEngine(cfg, params, max_batch=2, max_seq=16)
    eng2.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=2))
    stats2 = eng2.run()
    assert stats2["predicted_step_s"] is None
    assert "oracle_rel_error" not in stats2


def test_engine_reports_latency_percentiles(setup):
    """p50/p95 TTFT, per-request decode latency, and per-step percentiles
    — the serve-time check for the planner's latency claims."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=4))
    stats = eng.run()
    assert 0.0 < stats["p50_ttft_s"] <= stats["p95_ttft_s"]
    assert 0.0 <= stats["p50_decode_s"] <= stats["p95_decode_s"]
    assert 0.0 < stats["p50_step_s"] <= stats["p95_step_s"]
    # percentiles summarize the same samples the aggregates come from
    assert stats["p50_ttft_s"] <= max(
        r.t_first_token - r.t_submit for r in eng.done)
    assert stats["p95_step_s"] <= stats["decode_steps"] * stats[
        "measured_step_s"] + 1e-9
    # an idle engine reports zeroed percentiles, not NaN/crash
    empty = ServeEngine(cfg, params, max_batch=2, max_seq=24).run()
    for k in ("p50_ttft_s", "p95_ttft_s", "p50_decode_s", "p95_decode_s",
              "p50_step_s", "p95_step_s"):
        assert empty[k] == 0.0


def test_engine_batches_multiple_requests(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=24)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(6)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    stats = eng.run()
    assert stats["requests"] == 6
    assert stats["waves"] == 2          # 4 + 2 with max_batch=4
    assert stats["total_new_tokens"] == 24
    # batching must not cross-contaminate: request 0 alone == in batch
    solo = ServeEngine(cfg, params, max_batch=1, max_seq=24)
    solo.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
    solo.run()
    batched_r0 = next(r for r in eng.done if r.rid == 0)
    assert solo.done[0].output == batched_r0.output


def test_engine_mixed_length_prompts_wave_correctly(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    eng = ServeEngine(cfg, params, max_batch=8, max_seq=32)
    for i, L in enumerate((8, 8, 12, 12, 8)):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=L).astype(np.int32), max_new_tokens=2))
    stats = eng.run()
    assert stats["requests"] == 5
    assert stats["waves"] >= 2          # length groups cannot share a wave


def test_engine_serves_real_pruned_params_end_to_end():
    """Prune via the session front door, then serve the *pruned* params:
    decode outputs keep their shapes and the batch accounting adds up."""
    from repro.api import CPruneConfig, PruningSession, TrainHooks, Workload

    cfg = get_reduced_config("qwen3_1_7b").with_overrides(
        n_layers=2, d_model=64, d_ff=512, n_heads=8, n_kv_heads=2,
        head_dim=8, vocab_size=128)
    session = PruningSession(
        cfg, workload=Workload(tokens_global=8192),
        hooks=TrainHooks(short_term_train=lambda p, s: p,
                         eval_acc=lambda p, s: 0.9),
        pcfg=CPruneConfig(a_g=0.5, alpha=0.5, beta=0.9999,
                          max_iterations=2, seq_len=64))
    res = session.prune(strategy="cprune")
    assert any(h.accepted for h in res.history)
    ffn = next(s for s in res.sites if s.kind == "ffn")
    assert ffn.dim < cfg.d_ff                     # params really shrank
    assert res.params["stack"]["pos0"]["ffn"]["w_up"].shape[-1] == ffn.dim

    eng = session.serve(max_batch=4, max_seq=24)
    rng = np.random.default_rng(3)
    n_req, n_new = 6, 4
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=n_new))
    stats = eng.run()
    # batch accounting: every request finished with exactly its token budget
    assert stats["requests"] == n_req
    assert stats["waves"] == 2                    # 4 + 2 with max_batch=4
    assert stats["total_new_tokens"] == n_req * n_new
    for r in eng.done:
        assert r.done and len(r.output) == n_new
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    # pruned-model decode must match its own full-forward reference
    r0 = next(r for r in eng.done if r.rid == 0)
    expect = _greedy_reference(cfg, res.params, r0.prompt, n_new)
    assert r0.output == expect
